"""Compaction planning and execution (paper §4.2).

For each partition receiving new data the planner picks one of:
  abort  — keep new data in MemTable+WAL (minor WA ratio above threshold,
           subject to the 15 % global carry budget);
  minor  — write new tables, no rewrite of existing ones;
  major  — sort-merge the input-file subset with the best input/output ratio;
  split  — full merge into several new partitions (M tables each).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.db.partition import Partition, Table, chunk_table, merge_tables


@dataclasses.dataclass
class Plan:
    kind: str  # abort | minor | major | split
    partition: Partition
    new: Table | None  # new data destined for this partition
    major_inputs: int = 0  # number of (smallest) tables merged in a major
    est_wa: float = 0.0


@dataclasses.dataclass
class CompactionConfig:
    table_cap: int = 65536  # entries per table file (paper: 64 MB files)
    t_max: int = 10  # table-count threshold T for minor compaction
    wa_abort: float = 5.0  # abort when est. minor WA ratio exceeds this
    carry_budget: float = 0.15  # <= 15 % of new data may stay buffered
    split_ratio: float = 1.5  # major below this input/output ratio → split
    split_m: int = 2  # tables per new partition in a split


def plan_partition(p: Partition, new: Table, cfg: CompactionConfig) -> Plan:
    if new.n == 0:
        return Plan(kind="noop", partition=p, new=None)
    n_new_tables = max(1, math.ceil(new.n / cfg.table_cap))
    new_bytes = max(1, new.bytes())
    # §4.2 Abort: WA of a minor = (new tables + rebuilt REMIX) / new data
    est_minor_wa = (new_bytes + p.estimate_remix_bytes(new.n)) / new_bytes
    if len(p.tables) + n_new_tables <= cfg.t_max:
        return Plan(kind="minor", partition=p, new=new, est_wa=est_minor_wa)
    # need a major (or split): pick input count with best input/output ratio
    sizes = sorted(t.n for t in p.tables)
    best_k, best_ratio = 1, 0.0
    for k in range(1, len(sizes) + 1):
        merged = sum(sizes[:k]) + new.n
        n_out = max(1, math.ceil(merged / cfg.table_cap))
        total_after = len(sizes) - k + n_out
        if total_after > cfg.t_max and k < len(sizes):
            continue  # must keep reducing table count
        ratio = k / n_out
        if ratio > best_ratio:
            best_k, best_ratio = k, ratio
    if best_ratio < cfg.split_ratio:
        return Plan(kind="split", partition=p, new=new)
    return Plan(kind="major", partition=p, new=new, major_inputs=best_k)


def apply_abort_budget(plans: list[Plan], cfg: CompactionConfig) -> None:
    """Abort the highest-WA minors while within the 15 % carry budget."""
    total_new = sum(pl.new.n for pl in plans if pl.new is not None)
    if total_new == 0:
        return
    budget = int(total_new * cfg.carry_budget)
    minors = sorted(
        (pl for pl in plans if pl.kind == "minor"),
        key=lambda pl: -pl.est_wa,
    )
    for pl in minors:
        if pl.est_wa <= cfg.wa_abort:
            break
        if pl.new.n <= budget:
            budget -= pl.new.n
            pl.kind = "abort"


@dataclasses.dataclass
class ExecResult:
    bytes_written: int = 0
    # copy-on-write output: the partition(s) replacing the input in the
    # *next* Version. None only for noop/abort (input partition reused
    # as-is). The input partition is never mutated — readers pinning the
    # old Version keep a stable view.
    new_partitions: list[Partition] | None = None
    carried: Table | None = None  # aborted new data (stays in MemTable/WAL)
    # merge-side GC accounting: input rows dropped because an excised
    # span covered them / because their TTL had expired (store emits the
    # ttl_expired_dropped counter from the latter)
    rows_excised: int = 0
    rows_expired: int = 0


def _persist_tables(tables: list[Table], storage) -> None:
    """Write freshly produced tables through the SSTable writer (io layer);
    each gains a file path and (optionally) a CKB trailer."""
    if storage is None:
        return
    from repro.core import keys as CK

    for t in tables:
        name = storage.write_table(
            CK.pack_u64(t.keys), t.vals, t.seq, t.tomb,
            exp=t.exp if t.ttl_present() else None,
        )
        t.path = storage.table_path(name)


def execute(plan: Plan, cfg: CompactionConfig, storage=None,
            registry=None) -> ExecResult:
    """Execute one partition's plan; with a ``registry``, per-kind plan
    counters and an output-size histogram are recorded alongside the
    returned :class:`ExecResult` (the store aggregates the rest)."""
    res = _execute(plan, cfg, storage)
    if registry is not None:
        registry.counter("compaction_plans", kind=plan.kind).inc()
        if res.bytes_written:
            registry.histogram(
                "compaction_output_bytes", kind="bytes"
            ).observe(res.bytes_written)
    return res


def _execute(plan: Plan, cfg: CompactionConfig, storage=None) -> ExecResult:
    p = plan.partition
    if plan.kind in ("noop",):
        return ExecResult()
    if plan.kind == "abort":
        return ExecResult(carried=plan.new)
    if plan.kind == "minor":
        outs = chunk_table(plan.new, cfg.table_cap)
        _persist_tables(outs, storage)
        written = sum(t.bytes() for t in outs)
        # tables were only appended: the clone inherits the built REMIX
        # so index() rebuilds incrementally; its size counts toward WA
        p2 = p.clone_with_tables(list(p.tables) + outs, carry_built=True)
        p2.index()
        if storage is not None:
            p2.persist_index(storage)
        return ExecResult(
            bytes_written=written + p2.remix_bytes, new_partitions=[p2]
        )
    if plan.kind == "major":
        order = np.argsort([t.n for t in p.tables])
        chosen = [p.tables[i] for i in order[: plan.major_inputs]]
        keep = [p.tables[i] for i in order[plan.major_inputs :]]
        # excised spans mask their covered input rows out of the merge
        # (the outputs are then span-free); expired-TTL rows convert to
        # tombstones, which must keep hiding older versions in ``keep``
        st: dict = {}
        merged = merge_tables(chosen + [plan.new], excised=p.excised,
                              stats=st)
        outs = chunk_table(merged, cfg.table_cap)
        _persist_tables(outs, storage)
        p2 = p.clone_with_tables(keep + outs)  # table set changed: scratch
        p2.index()
        if storage is not None:
            p2.persist_index(storage)
        written = sum(t.bytes() for t in outs)
        return ExecResult(
            bytes_written=written + p2.remix_bytes, new_partitions=[p2],
            rows_excised=st.get("rows_excised", 0),
            rows_expired=st.get("rows_expired", 0),
        )
    if plan.kind == "split":
        # full merge (tombstones can be dropped: whole partition rewritten,
        # so excised/expired rows and the tombstones themselves all go)
        st = {}
        merged = merge_tables(p.tables + [plan.new], drop_tombs=True,
                              excised=p.excised, stats=st)
        outs = chunk_table(merged, cfg.table_cap)
        _persist_tables(outs, storage)
        written = sum(t.bytes() for t in outs)
        parts: list[Partition] = []
        m = cfg.split_m
        for i in range(0, max(1, len(outs)), m):
            group = outs[i : i + m]
            lo = p.lo if i == 0 else int(group[0].keys[0])
            np_ = Partition(lo=lo, tables=list(group), d=p.d)
            np_.index()
            if storage is not None:
                np_.persist_index(storage)
            written += np_.remix_bytes
            parts.append(np_)
        if not parts:  # everything deleted
            parts = [Partition(lo=p.lo, tables=[], d=p.d)]
        return ExecResult(bytes_written=written, new_partitions=parts,
                          rows_excised=st.get("rows_excised", 0),
                          rows_expired=st.get("rows_expired", 0))
    raise ValueError(plan.kind)

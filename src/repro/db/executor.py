"""Planner–executor for typed op batches: the physical half of the v2 API.

``Executor.submit(batch)`` turns a :class:`repro.db.ops.Batch` into a
future in three steps:

1. **Admission** — an in-flight byte budget shared by every batch of the
   engine. Submitters block (backpressure) while the budget is full; an
   op whose deadline expires while waiting is marked
   ``DEADLINE_EXCEEDED`` without poisoning the rest of the batch.
2. **Planning** — ops are split into *stages*: maximal runs of reads and
   writes in batch order (so a batch is always equivalent to the same
   ops issued sequentially through the legacy methods). Within a read
   stage, point lookups (Get + MultiGet fan-out) and scans are routed to
   their owning shard with the same ``route_host`` arithmetic the store
   uses internally, and grouped per shard for vectorized execution.
   MultiGets spanning shards fan out here and fan back in at execution.
3. **Execution** — a read stage pins **one snapshot per touched shard**
   (the store's ephemeral pinned view) for its whole duration, then
   compiles groups onto the engine's physical read primitives:
   ``_get_batch_at`` (vectorized cold/device point lookups) and
   ``_scan_group_at`` (vectorized window scans with the
   :class:`~repro.db.cursor.RemixCursor` fallback). Cross-shard scans
   drain shards in key order. A write stage routes rows to their owning
   shard and group-commits each shard's rows through the WAL in one
   append (``_apply_writes``).

Deadlines are re-checked when each group starts and inside cursor loops
(the ``interrupt`` hook), so a slow scan can be cut off mid-flight;
``future.cancel()`` cancels a queued batch outright and cooperatively
interrupts a running one between groups. Pinned snapshots are released
in ``finally`` blocks — a cancelled or failed batch never leaks a
Version pin.

Async submission runs on a small worker pool (daemon threads, started
lazily); ``submit(batch, sync=True)`` executes inline on the caller
thread and returns an already-completed future — the mode the legacy
wrapper methods use, so scalar ``put``/``get`` pay no thread hop.
"""
from __future__ import annotations

import concurrent.futures
import contextlib
import logging
import threading
import time

import numpy as np

from repro.db.ops import (
    Batch,
    BatchResult,
    Op,
    OpInterrupted,
    OpKind,
    OpResult,
    OpStatus,
    WRITE_KINDS,
)
from repro.db.sharded import partition_spans, route_host
from repro.io.faults import (
    CorruptionError,
    TransientIOError,
    UnavailableSpanError,
)
from repro.obs import events as _events
from repro.obs import metrics as _metrics
from repro.obs import tracing as _tracing

log = logging.getLogger(__name__)

# the typed storage failures (io.faults taxonomy): these mark the
# touching op IO_ERROR and trigger per-op isolation within a vectorized
# group, instead of the generic whole-group ERROR
_IO_ERRORS = (CorruptionError, TransientIOError, UnavailableSpanError)


def _status_for(e: BaseException) -> OpStatus:
    return OpStatus.IO_ERROR if isinstance(e, _IO_ERRORS) else OpStatus.ERROR


def _span(trace, name, **args):
    """Span context when tracing, free no-op otherwise."""
    if trace is None:
        return contextlib.nullcontext()
    return trace.span(name, **args)


def scan_batch_via_ops(engine: "Executor", starts, n: int
                       ) -> tuple[np.ndarray, np.ndarray]:
    """Legacy ``scan_batch`` shape — (keys (Q, n), valid (Q, n)) — via
    one keys-only Scan op per start. The single shared body behind
    ``RemixDB.scan_batch`` and ``KVServeEngine.scan_batch``."""
    starts = np.asarray(starts, np.uint64)
    ops = [Op.scan(int(s), int(n), with_vals=False)
           for s in starts.tolist()]
    res = engine.submit(Batch(ops), sync=True).result()
    q = len(starts)
    out_k = np.zeros((q, n), np.uint64)
    out_m = np.zeros((q, n), bool)
    for i, r in enumerate(res.results):
        r.raise_if_error()
        kk = r.keys[:n]
        out_k[i, : len(kk)] = kk
        out_m[i, : len(kk)] = True
    return out_k, out_m


class BatchFuture(concurrent.futures.Future):
    """Future for one submitted batch, with cooperative mid-run cancel.

    ``cancel()`` on a still-queued batch cancels it outright (the future
    raises ``CancelledError``). Once execution has started, ``cancel()``
    sets :attr:`interrupted` instead: ops not yet executed complete with
    ``OpStatus.CANCELLED`` and the future still resolves to a
    :class:`BatchResult`.
    """

    def __init__(self):
        super().__init__()
        self.interrupted = threading.Event()
        self._tickets = None  # sequencer tickets (shard -> turn number)
        self._order_waited = False

    def cancel(self) -> bool:
        if super().cancel():
            return True
        self.interrupted.set()
        return False


class AdmissionController:
    """Bounded in-flight bytes with blocking (backpressure) acquire."""

    def __init__(self, max_bytes: int,
                 registry: "_metrics.MetricsRegistry | None" = None):
        self.max_bytes = int(max_bytes)
        self.inflight = 0
        self.peak = 0
        reg = registry if registry is not None else _metrics.MetricsRegistry()
        self._c_admitted = reg.counter("admission_admitted")
        self._c_waits = reg.counter("admission_waits")
        reg.gauge("admission_inflight_bytes", fn=lambda: self.inflight)
        reg.gauge("admission_peak_bytes", fn=lambda: self.peak)
        reg.gauge("admission_max_bytes", fn=lambda: self.max_bytes)
        self._cv = threading.Condition()

    # legacy counter attributes — live views over the registry
    @property
    def admitted(self) -> int:
        return self._c_admitted.value

    @property
    def waits(self) -> int:
        """Acquires that had to block."""
        return self._c_waits.value

    def acquire(self, cost: int, deadline_at: float | None = None) -> bool:
        """Block until ``cost`` bytes fit in the budget; False when
        ``deadline_at`` (monotonic) passes first. A batch larger than
        the whole budget is admitted alone (sole occupancy) so it can
        never livelock."""
        cost = int(cost)
        with self._cv:
            waited = False
            while not (
                self.inflight + cost <= self.max_bytes or self.inflight == 0
            ):
                if not waited:
                    waited = True
                    self._c_waits.inc()
                timeout = None
                if deadline_at is not None:
                    timeout = deadline_at - time.monotonic()
                    if timeout <= 0:
                        return False
                self._cv.wait(timeout)
            self.inflight += cost
            self.peak = max(self.peak, self.inflight)
            self._c_admitted.inc()
            return True

    def release(self, cost: int) -> None:
        with self._cv:
            self.inflight -= int(cost)
            self._cv.notify_all()

    def stats(self) -> dict:
        with self._cv:
            return dict(
                max_bytes=self.max_bytes,
                inflight_bytes=self.inflight,
                peak_bytes=self.peak,
                admitted=self.admitted,
                waits=self.waits,
            )


class ShardSequencer:
    """Per-shard FIFO turn tickets: cross-batch write ordering.

    Async ``submit()`` alone promises nothing about the order two racing
    batches reach a shard's WAL. The sequencer hands each admitted batch
    one ticket per shard it will write (atomically, in submission
    order); a batch waits at its first write stage until every earlier
    ticket holder for those shards has *finished*, so per-shard write
    effects always land in submission order. Read-only batches take no
    tickets and are never delayed.

    Deadlock-free by construction: tickets are issued atomically with
    enqueue, so a batch only ever waits on strictly earlier batches, and
    the FIFO worker pool starts jobs in ticket order — a running batch's
    predecessors are always already running (or finished), never stuck
    behind it in the queue.
    """

    def __init__(self, n_shards: int):
        self._cv = threading.Condition()
        self._next = [0] * n_shards  # next ticket to issue, per shard
        self._done = [0] * n_shards  # all tickets < done have finished
        self._released: list[set] = [set() for _ in range(n_shards)]

    def register(self, shards) -> dict | None:
        """Issue one ticket per shard in ``shards``; None when empty."""
        if not shards:
            return None
        with self._cv:
            out = {}
            for s in shards:
                out[s] = self._next[s]
                self._next[s] += 1
            return out

    def await_turn(self, tickets: dict, interrupted=None) -> bool:
        """Block until every ticket is first in line (all earlier write
        batches for those shards finished). Returns False when
        ``interrupted`` was set while waiting — the caller's ops are
        about to be CANCELLED, so order no longer matters."""
        for s in sorted(tickets):
            t = tickets[s]
            with self._cv:
                while self._done[s] < t:
                    if interrupted is not None and interrupted.is_set():
                        return False
                    self._cv.wait(0.05 if interrupted is not None else None)
        return True

    def release(self, tickets: dict | None) -> None:
        """Mark a batch finished; out-of-order finishes (a cancelled
        batch ahead of the line) are parked until the line reaches
        them."""
        if not tickets:
            return
        with self._cv:
            for s, t in tickets.items():
                self._released[s].add(t)
                while self._done[s] in self._released[s]:
                    self._released[s].discard(self._done[s])
                    self._done[s] += 1
            self._cv.notify_all()


class _ReadGroup:
    """Per-(stage, shard) bundle of read work, vectorized at execution."""

    __slots__ = ("shard", "gets", "mgets", "scans", "priority")

    def __init__(self, shard: int):
        self.shard = shard
        self.gets: list[int] = []  # op indices
        # (op_idx, positions into op.keys routed to this shard)
        self.mgets: list[tuple[int, np.ndarray]] = []
        # with_vals -> op indices starting in this shard; scans of
        # different n share one heterogeneous group (merged row windows)
        self.scans: dict[bool, list[int]] = {}
        self.priority = 0


class _Stage:
    __slots__ = ("kind", "ops", "groups")

    def __init__(self, kind: str):
        self.kind = kind  # "read" | "write"
        self.ops: list[int] = []  # op indices in batch order
        self.groups: dict[int, _ReadGroup] = {}  # shard -> group (reads)


class Executor:
    """Plans and executes op batches over one or more range shards.

    ``shards`` is a list of ``(inclusive lower key bound, store)`` pairs
    — a single ``RemixDB`` uses ``[(0, db)]``; ``KVServeEngine`` passes
    its whole shard table so one batch fans out across stores.
    """

    def __init__(
        self,
        shards: list[tuple[int, object]],
        *,
        max_inflight_bytes: int = 64 << 20,
        workers: int = 2,
        registry: "_metrics.MetricsRegistry | None" = None,
        events: "_events.EventLog | None" = None,
        trace_sample_rate: float = 0.0,
    ):
        if not shards:
            raise ValueError("Executor needs at least one shard")
        shards = sorted(shards, key=lambda s: int(s[0]))
        self.lows = [int(lo) for lo, _ in shards]
        self.stores = [db for _, db in shards]
        # [lo, hi) key span each shard owns; scans are clipped to it so a
        # store holding out-of-span rows (e.g. the source of a live shard
        # split, which keeps the moved range's files) never leaks them
        self._spans = partition_spans(self.lows)
        self.sequencer = ShardSequencer(len(self.stores))
        self.vw = int(self.stores[0].cfg.vw)
        reg = registry if registry is not None else _metrics.MetricsRegistry()
        self.registry = reg
        self.events = events if events is not None else _events.NULL_EVENTS
        self.admission = AdmissionController(max_inflight_bytes, registry=reg)
        self._n_workers = max(1, int(workers))
        self._queue: list = []
        self._qcv = threading.Condition()
        self._threads: list[threading.Thread] = []
        self._closed = False
        # the op/batch counters the legacy ``stats()`` dict was built
        # from now live in the registry; ``stats()`` reads them back
        self._c_batches = reg.counter("engine_batches")
        self._c_completed = reg.counter("engine_batches_completed")
        self._c_cancelled_batches = reg.counter("engine_batches_cancelled")
        self._c_deadline = reg.counter("engine_ops_deadline_exceeded")
        self._c_cancelled_ops = reg.counter("engine_ops_cancelled")
        self._c_errors = reg.counter("engine_ops_errors")
        self._c_io_errors = reg.counter("engine_ops_io_errors")
        self._c_batch_failures = reg.counter("engine_batch_failures")
        self._c_ops = {
            k.value: reg.counter("engine_ops", kind=k.value) for k in OpKind
        }
        self._h_batch = reg.histogram("engine_batch_seconds")
        self._h_wait = reg.histogram("engine_admission_wait_seconds")
        reg.gauge("engine_queue_depth", fn=lambda: len(self._queue))
        reg.gauge("engine_workers", fn=lambda: len(self._threads))
        self._c_ordered = reg.counter("engine_ordered_batches")
        self._sampler = _tracing.Sampler(trace_sample_rate)
        self._c_traced = reg.counter("engine_batches_traced")
        self.last_trace: "_tracing.Trace | None" = None

    # ---------------- submission ----------------
    def submit(self, batch: Batch | list, *, sync: bool = False
               ) -> BatchFuture:
        """Admit + enqueue ``batch``; returns a future resolving to a
        :class:`BatchResult`. With ``sync=True`` the batch executes
        inline on the calling thread (the future returned is already
        done) — identical semantics, no thread hop."""
        if isinstance(batch, (list, tuple)):
            batch = Batch(list(batch))
        if self._closed and not sync:
            # close() only retires the async worker pool; synchronous
            # submission (and with it every legacy wrapper) keeps
            # working, matching the stores' own close-then-read contract
            raise RuntimeError("executor is closed to async submissions")
        now = time.monotonic()
        deadlines = [
            None if op.deadline_ms is None else now + op.deadline_ms / 1e3
            for op in batch.ops
        ]
        self._c_batches.inc()
        for op in batch.ops:
            self._c_ops[op.kind.value].inc()
        trace = None
        if getattr(batch, "trace", False) or self._sampler.should_sample():
            trace = _tracing.Trace(
                "batch", args=dict(ops=len(batch.ops), sync=bool(sync))
            )
            trace.sampled = not getattr(batch, "trace", False)
            self._c_traced.inc()
        fut = BatchFuture()
        results: list[OpResult | None] = [None] * len(batch.ops)
        t0 = time.monotonic()
        ta = _tracing.now()
        cost = self._admit(batch, deadlines, results)
        wait_s = time.monotonic() - t0
        self._h_wait.observe(wait_s)
        if trace is not None:
            trace.leaf("admission", ta, _tracing.now(), bytes=cost)
        t_sub = time.monotonic()
        if all(r is not None for r in results):  # every op expired waiting
            self._finish(fut, batch, results, cost, wait_s, started=False,
                         trace=trace, t_sub=t_sub)
            return fut
        if sync:
            self._register_order(fut, batch)
            self._run(fut, batch, deadlines, results, cost, wait_s,
                      trace=trace, t_sub=t_sub)
            return fut
        with self._qcv:
            self._ensure_workers()
            # ticket issue and enqueue are atomic (same lock), so queue
            # order == ticket order and a worker never starts a batch
            # whose predecessor is still stuck behind it in the queue
            self._register_order(fut, batch)
            self._queue.append((fut, batch, deadlines, results, cost, wait_s,
                                trace, _tracing.now(), t_sub))
            self._qcv.notify()
        return fut

    def _register_order(self, fut, batch) -> None:
        """Issue per-shard write tickets (post-admission, so a batch
        waiting on its turn always holds budget and its predecessors do
        too — no admission/ordering deadlock)."""
        shards = self._write_shards(batch)
        fut._tickets = self.sequencer.register(shards)
        if fut._tickets:
            self._c_ordered.inc()

    def _write_shards(self, batch) -> list[int]:
        """Shards the batch will write, for sequencer tickets."""
        if len(self.lows) == 1:
            if any(op.kind in WRITE_KINDS for op in batch.ops):
                return [0]
            return []
        out: set[int] = set()
        for op in batch.ops:
            if op.kind not in WRITE_KINDS:
                continue
            if op.kind is OpKind.DELETE_RANGE:
                for si, (lo, hi) in enumerate(self._spans):
                    if max(op.start, lo) < min(op.end, hi):
                        out.add(si)
            elif op.keys is not None:
                sids = route_host(
                    self.lows, np.asarray(op.keys, np.uint64)
                )
                out.update(int(s) for s in np.unique(sids))
            else:
                out.add(self._route_one(op.key))
        return sorted(out)

    def _release_order(self, fut) -> None:
        tickets = getattr(fut, "_tickets", None)
        fut._tickets = None
        self.sequencer.release(tickets)

    def execute(self, batch: Batch | list) -> BatchResult:
        """Synchronous convenience: ``submit(batch, sync=True).result()``."""
        return self.submit(batch, sync=True).result()

    def _admit(self, batch, deadlines, results) -> int:
        """Admission loop: blocks for budget; ops whose deadline passes
        while waiting are individually expired and give their bytes
        back. Returns the admitted cost (of still-live ops)."""
        while True:
            live = [i for i, r in enumerate(results) if r is None]
            cost = sum(batch.ops[i].cost_bytes(self.vw) for i in live)
            if not live:
                return 0
            dls = [deadlines[i] for i in live if deadlines[i] is not None]
            earliest = min(dls) if dls else None
            if self.admission.acquire(cost, earliest):
                return cost
            # earliest deadline fired while queued: expire what's due,
            # then retry admission with the slimmer batch
            now = time.monotonic()
            for i in live:
                if deadlines[i] is not None and deadlines[i] <= now:
                    results[i] = OpResult(status=OpStatus.DEADLINE_EXCEEDED)

    # ---------------- worker pool ----------------
    def _ensure_workers(self) -> None:
        while len(self._threads) < self._n_workers:
            t = threading.Thread(target=self._worker, daemon=True)
            t.start()
            self._threads.append(t)

    def _worker(self) -> None:
        while True:
            with self._qcv:
                while not self._queue and not self._closed:
                    self._qcv.wait()
                if not self._queue:
                    return  # closed + drained
                job = self._queue.pop(0)
            (fut, batch, deadlines, results, cost, wait_s,
             trace, t_enq, t_sub) = job
            if trace is not None:
                trace.leaf("queue", t_enq, _tracing.now())
            if not fut.set_running_or_notify_cancel():
                # cancelled while queued: give the bytes back, count ops
                self.admission.release(cost)
                self._release_order(fut)
                self._c_cancelled_batches.inc()
                continue
            self._run(fut, batch, deadlines, results, cost, wait_s,
                      trace=trace, t_sub=t_sub, mark_running=False)

    def _run(self, fut, batch, deadlines, results, cost, wait_s,
             trace=None, t_sub=None, mark_running=True) -> None:
        if mark_running and not fut.set_running_or_notify_cancel():
            self.admission.release(cost)
            self._release_order(fut)
            self._c_cancelled_batches.inc()
            return
        try:
            with _tracing.activate(trace):
                self._execute(fut, batch, deadlines, results, trace)
        except BaseException as e:  # plan-level failure: fail leftover ops
            for i, r in enumerate(results):
                if r is None:
                    results[i] = OpResult(status=OpStatus.ERROR,
                                          error=repr(e), exc=e)
            # structured failure path: a background batch failure lands
            # in the event log + logging, not on a worker's stderr
            self._c_batch_failures.inc()
            self.events.emit("batch_error", error=repr(e),
                             ops=len(batch.ops))
            log.exception("op batch execution failed (%d ops)",
                          len(batch.ops))
        self._finish(fut, batch, results, cost, wait_s, started=True,
                     trace=trace, t_sub=t_sub)

    def _finish(self, fut, batch, results, cost, wait_s, started,
                trace=None, t_sub=None) -> None:
        self.admission.release(cost)
        self._release_order(fut)
        stats = self._batch_stats(batch, results, wait_s, started)
        self._c_completed.inc()
        self._c_deadline.inc(stats["deadline_exceeded"])
        self._c_cancelled_ops.inc(stats["cancelled"])
        self._c_errors.inc(stats["errors"])
        self._c_io_errors.inc(stats["io_errors"])
        if t_sub is not None:
            self._h_batch.observe(time.monotonic() - t_sub)
        if trace is not None:
            trace.finish()
            self.last_trace = trace
        if fut.cancelled():
            return  # raced a queue-level cancel
        fut.set_result(BatchResult(list(results), stats, trace=trace))

    def _batch_stats(self, batch, results, wait_s, started) -> dict:
        by_status: dict[str, int] = {}
        for r in results:
            by_status[r.status.value] = by_status.get(r.status.value, 0) + 1
        kinds: dict[str, int] = {}
        for op in batch.ops:
            kinds[op.kind.value] = kinds.get(op.kind.value, 0) + 1
        return dict(
            ops=len(batch.ops),
            kinds=kinds,
            status=by_status,
            executed=bool(started),
            admission_wait_s=round(wait_s, 6),
            deadline_exceeded=by_status.get("deadline_exceeded", 0),
            cancelled=by_status.get("cancelled", 0),
            errors=by_status.get("error", 0),
            io_errors=by_status.get("io_error", 0),
        )

    # ---------------- planning ----------------
    def plan(self, batch: Batch) -> list[_Stage]:
        """Split ops into read/write stages and route read work to
        shards. Public for introspection and tests; execution consumes
        exactly this structure."""
        stages: list[_Stage] = []
        for i, op in enumerate(batch.ops):
            kind = "write" if op.kind in WRITE_KINDS else "read"
            if not stages or stages[-1].kind != kind:
                stages.append(_Stage(kind))
            st = stages[-1]
            st.ops.append(i)
            if kind != "read":
                continue
            if op.kind is OpKind.GET:
                g = self._group(st, self._route_one(op.key))
                g.gets.append(i)
                g.priority = max(g.priority, op.priority)
            elif op.kind is OpKind.MULTIGET:
                if len(op.keys) == 0:
                    # empty fan-out still needs a home so the op
                    # resolves to an empty OK result
                    g = self._group(st, 0)
                    g.mgets.append((i, np.zeros(0, np.int64)))
                    continue
                if len(self.lows) == 1:
                    sids = np.zeros(len(op.keys), np.int64)
                else:
                    sids = route_host(self.lows, op.keys)
                for s in np.unique(sids):
                    g = self._group(st, int(s))
                    g.mgets.append((i, np.flatnonzero(sids == s)))
                    g.priority = max(g.priority, op.priority)
            else:  # SCAN: starts in its owning shard, may drain onward
                g = self._group(st, self._route_one(op.start))
                g.scans.setdefault(op.with_vals, []).append(i)
                g.priority = max(g.priority, op.priority)
        return stages

    def _group(self, stage: _Stage, shard: int) -> _ReadGroup:
        g = stage.groups.get(shard)
        if g is None:
            g = stage.groups[shard] = _ReadGroup(shard)
        return g

    def _route_one(self, key: int) -> int:
        if len(self.lows) == 1:
            return 0
        return int(route_host(self.lows, np.array([key], np.uint64))[0])

    # ---------------- execution ----------------
    def _execute(self, fut, batch, deadlines, results, trace=None) -> None:
        with _span(trace, "plan"):
            stages = self.plan(batch)
        for idx, stage in enumerate(stages):
            with _span(trace, f"stage{idx}:{stage.kind}",
                       ops=len(stage.ops)):
                if stage.kind == "write":
                    if fut._tickets and not fut._order_waited:
                        # first write of the batch: wait for every
                        # earlier write batch touching these shards
                        fut._order_waited = True
                        with _span(trace, "sequence"):
                            self.sequencer.await_turn(
                                fut._tickets, fut.interrupted
                            )
                    self._exec_write_stage(
                        fut, batch, deadlines, results, stage, trace
                    )
                else:
                    self._exec_read_stage(
                        fut, batch, deadlines, results, stage, trace
                    )

    def _precheck(self, fut, deadlines, results, idxs) -> list[int]:
        """Mark cancelled/expired ops among ``idxs``; return survivors."""
        now = time.monotonic()
        out = []
        for i in idxs:
            if results[i] is not None:
                continue
            if fut.interrupted.is_set():
                results[i] = OpResult(status=OpStatus.CANCELLED)
            elif deadlines[i] is not None and deadlines[i] <= now:
                results[i] = OpResult(status=OpStatus.DEADLINE_EXCEEDED)
            else:
                out.append(i)
        return out

    def _interrupt_for(self, fut, deadline_at):
        """Cooperative checker threaded into cursor loops (mid-op
        deadline/cancel), or None when the op can't be interrupted."""
        if deadline_at is None:
            def check():
                if fut.interrupted.is_set():
                    raise OpInterrupted(OpStatus.CANCELLED)
        else:
            def check():
                if fut.interrupted.is_set():
                    raise OpInterrupted(OpStatus.CANCELLED)
                if time.monotonic() > deadline_at:
                    raise OpInterrupted(OpStatus.DEADLINE_EXCEEDED)
        return check

    # ---- writes ----
    def _exec_write_stage(self, fut, batch, deadlines, results, stage,
                          trace=None):
        live = self._precheck(fut, deadlines, results, stage.ops)
        if not live:
            return
        # Put/Delete rows accumulate per shard and group-commit together;
        # a DeleteRange or Cas is a *write edge* — accumulated rows flush
        # first so per-shard effects equal the sequential legacy order
        # (a Cas must observe every earlier write in its own batch)
        per: dict[int, list[tuple]] = {}
        pending: list[int] = []

        def commit_pending():
            for shard in sorted(per):
                chunks = per[shard]
                keys = np.concatenate([c[0] for c in chunks])
                vals = np.concatenate([c[1] for c in chunks])
                tombs = np.concatenate(
                    [np.full(len(c[0]), c[2], bool) for c in chunks]
                )
                exps = np.concatenate([c[3] for c in chunks])
                # one WAL group commit + MemTable apply per shard
                with _span(trace, f"shard{shard}:commit", rows=len(keys)):
                    self.stores[shard]._apply_writes(keys, vals, tombs,
                                                     exps=exps)
            per.clear()
            for j in pending:
                results[j] = OpResult(status=OpStatus.OK)
            pending.clear()

        try:
            for i in live:
                op = batch.ops[i]
                if op.kind is OpKind.DELETE_RANGE:
                    commit_pending()
                    with _span(trace, "delete_range"):
                        self._apply_delete_range_op(op)
                    results[i] = OpResult(status=OpStatus.OK)
                    continue
                if op.kind is OpKind.CAS:
                    commit_pending()
                    shard = self._route_one(op.key)
                    with _span(trace, f"shard{shard}:cas"):
                        ok, actual = self.stores[shard]._apply_cas(
                            op.key, op.expect, op.val, exp=int(op.exp)
                        )
                    results[i] = OpResult(status=OpStatus.OK, found=ok,
                                          value=actual)
                    continue
                tomb = op.kind is OpKind.DELETE
                if op.keys is None:
                    keys = np.array([op.key], np.uint64)
                    vals = (
                        np.zeros((1, self.vw), np.uint32)
                        if tomb
                        else np.asarray(op.val, np.uint32).reshape(
                            1, self.vw
                        )
                    )
                else:
                    keys = np.asarray(op.keys, np.uint64)
                    vals = (
                        np.zeros((len(keys), self.vw), np.uint32)
                        if tomb or op.val is None
                        else np.asarray(op.val, np.uint32).reshape(
                            len(keys), self.vw
                        )
                    )
                exps = np.broadcast_to(
                    np.asarray(op.exp, np.uint32), (len(keys),)
                ).copy()
                pending.append(i)
                if len(self.lows) == 1:
                    per.setdefault(0, []).append((keys, vals, tomb, exps))
                else:
                    sids = route_host(self.lows, keys)
                    for s in np.unique(sids):
                        m = sids == s
                        per.setdefault(int(s), []).append(
                            (keys[m], vals[m], tomb, exps[m])
                        )
            commit_pending()
        except Exception as e:
            # a write stage commits as one WAL group append per shard, so
            # a typed I/O failure (e.g. fsync giving up) fails the whole
            # stage — but with the typed status so callers can tell a
            # storage fault from a logic error
            for i in live:
                if results[i] is None:
                    results[i] = OpResult(status=_status_for(e),
                                          error=repr(e), exc=e)
            return

    def _apply_delete_range_op(self, op) -> None:
        """Fan one DeleteRange out across shards, clipped to each shard's
        key span — shards outside [start, end) are untouched."""
        if len(self.lows) == 1:
            self.stores[0]._apply_delete_range(op.start, op.end)
            return
        for si, (lo, hi) in enumerate(partition_spans(self.lows)):
            l, h = max(op.start, lo), min(op.end, hi)
            if l < h:
                self.stores[si]._apply_delete_range(l, h)

    # ---- reads ----
    def _exec_read_stage(self, fut, batch, deadlines, results, stage,
                         trace=None):
        groups = sorted(
            stage.groups.values(), key=lambda g: (-g.priority, g.shard)
        )
        # one pinned snapshot per touched shard, held for the whole stage
        # (scan drains pin follow-on shards through the same table)
        with contextlib.ExitStack() as stack:
            views: dict[int, object] = {}

            def view(shard: int):
                v = views.get(shard)
                if v is None:
                    v = stack.enter_context(self.stores[shard]._view())
                    views[shard] = v
                return v

            # MultiGet fan-in buffers: op_idx -> (found, vals)
            mg: dict[int, list] = {}
            for g in groups:
                with _span(trace, f"shard{g.shard}:read",
                           gets=len(g.gets) + len(g.mgets),
                           scans=sum(len(v) for v in g.scans.values())):
                    self._exec_points(
                        fut, batch, deadlines, results, g, view, mg
                    )
                    self._exec_scans(fut, batch, deadlines, results, g, view)
            for i, (found, vals) in mg.items():
                if results[i] is None:
                    results[i] = OpResult(
                        status=OpStatus.OK, found=found, vals=vals
                    )

    def _exec_points(self, fut, batch, deadlines, results, g, view, mg):
        gets = self._precheck(fut, deadlines, results, g.gets)
        mgets = [
            (i, pos)
            for i, pos in g.mgets
            if results[i] is None
            and self._precheck(fut, deadlines, results, [i])
        ]
        keys: list[np.ndarray] = []
        for i in gets:
            keys.append(np.array([batch.ops[i].key], np.uint64))
        for i, pos in mgets:
            if i not in mg:
                q = len(batch.ops[i].keys)
                mg[i] = [np.zeros(q, bool),
                         np.zeros((q, self.vw), np.uint32)]
            keys.append(np.asarray(batch.ops[i].keys, np.uint64)[pos])
        if not keys:
            return
        if len(gets) == 1 and not mgets and len(keys[0]) == 1:
            # lone point lookup: the scalar read path (same results as the
            # batched one — tested — but with the bounded per-key byte
            # profile legacy ``db.get`` had)
            i = gets[0]
            try:
                val = self.stores[g.shard]._get_at(
                    view(g.shard), batch.ops[i].key
                )
            except Exception as e:
                results[i] = OpResult(status=_status_for(e), error=repr(e),
                                      exc=e)
                return
            results[i] = OpResult(
                status=OpStatus.OK, found=val is not None, value=val
            )
            return
        qk = np.concatenate(keys)
        try:
            found, vals = self.stores[g.shard]._get_batch_at(view(g.shard), qk)
        except _IO_ERRORS:
            # containment: one corrupt granule must fail only the ops
            # whose keys touch it — re-execute the group per op so the
            # rest of the batch completes normally
            self._points_isolated(batch, results, g, view, gets, mgets, mg)
            return
        except Exception as e:
            for i in gets:
                results[i] = OpResult(status=OpStatus.ERROR, error=repr(e), exc=e)
            for i, _ in mgets:
                results[i] = OpResult(status=OpStatus.ERROR, error=repr(e), exc=e)
            return
        off = 0
        for i in gets:
            results[i] = OpResult(
                status=OpStatus.OK,
                found=bool(found[off]),
                value=vals[off].copy() if found[off] else None,
            )
            off += 1
        for i, pos in mgets:
            m = len(pos)
            mg[i][0][pos] = found[off : off + m]
            mg[i][1][pos] = vals[off : off + m]
            off += m

    def _points_isolated(self, batch, results, g, view, gets, mgets, mg):
        """Per-op fallback after a typed I/O failure in the vectorized
        point group: each op re-reads alone, so only ops whose keys land
        on the corrupt granule end IO_ERROR."""
        for i in gets:
            try:
                val = self.stores[g.shard]._get_at(
                    view(g.shard), batch.ops[i].key
                )
            except Exception as e:
                results[i] = OpResult(status=_status_for(e), error=repr(e),
                                      exc=e)
                continue
            results[i] = OpResult(
                status=OpStatus.OK, found=val is not None, value=val
            )
        for i, pos in mgets:
            try:
                f, v = self.stores[g.shard]._get_batch_at(
                    view(g.shard),
                    np.asarray(batch.ops[i].keys, np.uint64)[pos],
                )
            except Exception as e:
                results[i] = OpResult(status=_status_for(e), error=repr(e),
                                      exc=e)
                continue
            mg[i][0][pos] = f
            mg[i][1][pos] = v

    def _exec_scans(self, fut, batch, deadlines, results, g, view):
        for with_vals, idxs in g.scans.items():
            live = self._precheck(fut, deadlines, results, idxs)
            if not live:
                continue
            starts = np.array(
                [batch.ops[i].start for i in live], np.uint64
            )
            ns = np.array([batch.ops[i].n for i in live], np.int64)
            checks = [
                self._interrupt_for(fut, deadlines[i]) for i in live
            ]
            try:
                rows = self.stores[g.shard]._scan_group_at(
                    view(g.shard), starts, ns,
                    with_vals=with_vals, interrupts=checks,
                )
            except _IO_ERRORS:
                # containment: re-run each scan alone so only the ones
                # crossing the corrupt granule end IO_ERROR; survivors
                # rejoin the common drain/fan-out loop below
                rows = []
                for i, chk in zip(live, checks):
                    try:
                        kk, vv = self.stores[g.shard]._scan_at(
                            view(g.shard), batch.ops[i].start,
                            batch.ops[i].n, interrupt=chk,
                        )
                        rows.append((kk, vv if with_vals else None))
                    except OpInterrupted as e2:
                        rows.append(e2)
                    except Exception as e2:
                        results[i] = OpResult(status=_status_for(e2),
                                              error=repr(e2), exc=e2)
                        rows.append(None)
            except Exception as e:
                for i in live:
                    results[i] = OpResult(status=OpStatus.ERROR,
                                          error=repr(e), exc=e)
                continue
            for i, row in zip(live, rows):
                if row is None:  # failed in the isolation fallback
                    continue
                if isinstance(row, OpInterrupted):
                    results[i] = OpResult(status=row.status)
                    continue
                kk, vv = row
                kk, vv = self._clip_to_span(g.shard, kk, vv)
                try:
                    kk, vv = self._drain_scan(
                        fut, deadlines[i], g.shard, kk, vv,
                        batch.ops[i].n, with_vals, view,
                    )
                except OpInterrupted as e:
                    results[i] = OpResult(status=e.status)
                    continue
                except Exception as e:
                    results[i] = OpResult(status=_status_for(e),
                                          error=repr(e), exc=e)
                    continue
                results[i] = OpResult(status=OpStatus.OK, keys=kk, vals=vv)

    def _clip_to_span(self, shard: int, kk, vv):
        """Drop scan rows past the shard's owned [lo, hi) span. Rows are
        ascending, so a tail mask suffices; the last shard (hi = 2^64)
        never clips."""
        hi = self._spans[shard][1]
        if hi >= (1 << 64) or len(kk) == 0 or int(kk[-1]) < hi:
            return kk, vv
        keep = int(np.searchsorted(kk, np.uint64(hi), side="left"))
        return kk[:keep], None if vv is None else vv[:keep]

    def _drain_scan(self, fut, deadline_at, shard, kk, vv, n, with_vals,
                    view):
        """Cross-shard fan-out of one scan: drain follow-on shards in key
        order until ``n`` rows (the serve engine's legacy drain rule)."""
        si = shard + 1
        check = self._interrupt_for(fut, deadline_at)
        while len(kk) < n and si < len(self.stores):
            check()
            k2, v2 = self.stores[si]._scan_at(
                view(si), self.lows[si], n - len(kk), interrupt=check
            )
            k2, v2 = self._clip_to_span(si, k2, v2)
            kk = np.concatenate([kk, k2])
            if with_vals:
                vv = np.concatenate([vv, v2])
            si += 1
        return kk, vv

    # ---------------- lifecycle / stats ----------------
    def close(self, wait: bool = True) -> None:
        """Stop accepting batches; drain the async queue (``wait``)."""
        with self._qcv:
            self._closed = True
            self._qcv.notify_all()
        if wait:
            for t in self._threads:
                t.join()

    def stats(self) -> dict:
        """Legacy stats dict — a view reading the registry counters back
        out (bit-compatible with the pre-registry ``_counts`` layout)."""
        with self._qcv:
            qd, wk = len(self._queue), len(self._threads)
        out = dict(
            batches=self._c_batches.value,
            completed=self._c_completed.value,
            cancelled_batches=self._c_cancelled_batches.value,
            ops={k.value: self._c_ops[k.value].value for k in OpKind},
            deadline_exceeded=self._c_deadline.value,
            cancelled_ops=self._c_cancelled_ops.value,
            errors=self._c_errors.value,
            io_errors=self._c_io_errors.value,
        )
        out["queue_depth"] = qd
        out["workers"] = wk
        out["admission"] = self.admission.stats()
        out["shards"] = len(self.stores)
        return out

"""RemixCursor: the paper's cursor (§3.2 seek/peek/next/skip) over a
snapshot-consistent merged view.

One cursor unifies the store's three read paths behind a single ascending
stream of live ``(key, value)`` entries:

- the MemTable overlay (the snapshot's frozen entry dict, tombstones
  hiding older table entries),
- cold partitions (on-disk REMIX walk: one anchors search + bounded CKB
  seeks at ``seek``, then pure selector-stream decodes per window —
  :meth:`repro.db.partition.Partition.cold_cursor_window`),
- promoted partitions (device REMIX: one jitted ``seek``, then
  comparison-free ``gather_view`` windows from the saved position).

The defining property vs repeated ``scan(start, n)`` calls: a cursor
seeks **once**. ``next``/``next_batch`` advance a persisted view
position, so a long or streaming scan pays the anchors search and
per-run seeks a single time instead of once per chunk
(``benchmarks/cursor_bench.py`` holds the ≥2x acceptance bar). ``skip``
counts live entries, draining windows without materializing values'
consumers. Because the snapshot pins its Version, iteration is immune to
concurrent flushes: a compaction publishing a new Version never changes
what an open cursor returns.
"""
from __future__ import annotations

import bisect

import numpy as np

from repro.core import keys as CK
from repro.db import clock
from repro.db.memtable import entry_dead
from repro.db.sharded import partition_spans, route_one

_MAX_WIDTH = 4096  # widening cap over tombstone/old-version runs


class RemixCursor:
    """Merged-view iterator over a :class:`repro.db.version.Snapshot`."""

    def __init__(self, snapshot, width: int = 64,
                 owns_snapshot: bool = False, interrupt=None):
        if width < 1:
            raise ValueError("cursor width must be >= 1")
        self.snap = snapshot
        self.store = snapshot.store
        self.base_width = int(width)
        self.vw = self.store.cfg.vw
        self._owns = owns_snapshot
        # cooperative cancellation hook (op layer): called once per
        # window pull; raising aborts the fill — a deadline-bounded scan
        # stops mid-stream instead of draining the whole range
        self._interrupt = interrupt
        # buffered live entries, as (keys, vals) array chunks: windows
        # with no interleaving overlay entries pass through zero-copy
        self._chunks: list[tuple[np.ndarray, np.ndarray]] = []
        self._buffered = 0
        self._done = True
        self._stream = None

    # ---------------- positioning ----------------
    def seek(self, key: int) -> "RemixCursor":
        """Position at the lower bound of ``key`` in the merged view."""
        self._start = int(key)
        parts = self.snap.partitions
        self._spans = partition_spans([p.lo for p in parts])
        if self.snap.shared:
            # the overlay is the live MemTable dict: materialize the key
            # list under the writer lock so a concurrent put's dict
            # resize can't tear the iteration
            with self.store._state_lock:
                self._okeys = sorted(self.snap.overlay)
        else:
            self._okeys = sorted(self.snap.overlay)
        self._oi = bisect.bisect_left(self._okeys, self._start)
        self._pi = route_one(parts, self._start)
        self._first = True
        self._stream = None
        self._width = self.base_width
        self._chunks = []
        self._buffered = 0
        self._done = False
        return self

    # ---------------- consumption ----------------
    def peek(self):
        """The next live entry ``(key, val)`` without advancing, or None."""
        self._fill(1)
        if not self._chunks:
            return None
        kk, vv = self._chunks[0]
        return int(kk[0]), vv[0]

    def next(self):
        """Return the next live entry ``(key, val)`` and advance, or None
        at end of view."""
        item = self.peek()
        if item is not None:
            self._drop(1)
        return item

    def skip(self, n: int) -> int:
        """Advance past ``n`` live entries; returns how many were skipped
        (fewer only at end of view)."""
        self._fill(n)
        got = min(n, self._buffered)
        self._drop(got)
        return got

    def next_batch(self, n: int) -> tuple[np.ndarray, np.ndarray]:
        """The next ``n`` live entries as ``(keys (M,) u64, vals (M, VW))``
        arrays, M <= n — the batched ``next`` that makes ``scan`` a thin
        wrapper over a cursor."""
        self._fill(n)
        take_k: list[np.ndarray] = []
        take_v: list[np.ndarray] = []
        need = n
        while need > 0 and self._chunks:
            kk, vv = self._chunks[0]
            if len(kk) <= need:
                self._chunks.pop(0)
            else:
                self._chunks[0] = (kk[need:], vv[need:])
                kk, vv = kk[:need], vv[:need]
            take_k.append(kk)
            take_v.append(vv)
            need -= len(kk)
            self._buffered -= len(kk)
        if not take_k:
            return (
                np.zeros(0, np.uint64),
                np.zeros((0, self.vw), np.uint32),
            )
        return np.concatenate(take_k), np.concatenate(take_v)

    def _drop(self, n: int) -> None:
        while n > 0 and self._chunks:
            kk, vv = self._chunks[0]
            if len(kk) <= n:
                self._chunks.pop(0)
                n -= len(kk)
                self._buffered -= len(kk)
            else:
                self._chunks[0] = (kk[n:], vv[n:])
                self._buffered -= n
                n = 0

    # ---------------- lifecycle ----------------
    def close(self) -> None:
        """Release the snapshot if this cursor owns it (see
        ``RemixDB.cursor``); cursors over caller-managed snapshots leave
        them open."""
        if self._owns:
            self.snap.close()

    def __enter__(self) -> "RemixCursor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __iter__(self):
        while True:
            item = self.next()
            if item is None:
                return
            yield item

    # ---------------- internals ----------------
    def _open_stream(self):
        """Start the table-entry stream of the current partition: one
        seek (cold: anchors + bounded CKB; promoted: jitted device seek),
        after which every window is a pure position advance."""
        p = self.snap.partitions[self._pi]
        lo, _ = self._spans[self._pi]
        start = max(self._start, lo) if self._first else lo
        self._first = False
        self._width = self.base_width
        if self.store._cold_ok(p):
            self._stream = ("cold", p, p.cold_cursor_seek(start))
            return
        import jax.numpy as jnp

        remix, runset = p.index()
        qk = jnp.asarray(CK.pack_u64(np.array([start], np.uint64)))
        pos = int(
            np.asarray(
                self.store._query_mod().seek(
                    remix, runset, qk, **self.store._qkw()
                )
            )[0]
        )
        self._stream = ["dev", p, remix, runset, pos]

    def _next_window(self):
        """One window of live table entries from the current partition.
        Returns (keys u64, vals, partition_done)."""
        _, hi = self._spans[self._pi]
        if self._stream[0] == "cold":
            _, p, state = self._stream
            kk, vv, more = p.cold_cursor_window(
                state, self._width,
                prefetch_depth=self.store.cfg.prefetch_depth,
            )
        else:
            _, p, remix, runset, pos = self._stream
            import jax.numpy as jnp

            keys, vals, valid = self.store._query_mod().gather_view(
                remix, runset, jnp.asarray([pos], jnp.int32), self._width
            )
            v0 = np.asarray(valid)[0]
            kk = CK.unpack_u64(np.asarray(keys)[0][v0])
            vv = np.asarray(vals)[0][v0]
            more = pos + self._width < remix.n_slots
            self._stream[4] = pos + self._width
        # clip to the partition's key range; entries at/after the next
        # partition's lower bound mean this partition is drained
        cut = int(np.searchsorted(kk, np.uint64(min(hi, (1 << 64) - 1)),
                                  side="right" if hi >= 1 << 64 else "left"))
        clipped = cut < len(kk)
        kk, vv = kk[:cut], vv[:cut]
        # snapshot-visible range tombstones hide any remaining table
        # entries they cover (partial-coverage spans and promoted-path
        # windows; fully-covered cold spans were skipped structurally)
        if self.snap.ranges and len(kk):
            m = np.ones(len(kk), bool)
            for rlo, rhi, _ in self.snap.ranges:
                m &= ~((kk >= rlo) & (kk < rhi))
            kk, vv = kk[m], vv[m]
        # adaptive widening, two cases sharing one rule: an all-invalid
        # window (tombstone/old-version run) must grow so long dead runs
        # cost O(log) decodes, and a productive stream grows as read-ahead
        # — the first window stays small (seek latency), sustained
        # consumption amortizes per-window overhead over ever larger
        # decodes. Re-seeking scans can't do this: read-ahead is only
        # free when the position survives the call.
        self._width = min(self._width * 2, _MAX_WIDTH)
        return kk, vv, clipped or not more

    def _push(self, kk: np.ndarray, vv: np.ndarray) -> None:
        if len(kk):
            self._chunks.append((kk, vv))
            self._buffered += len(kk)

    def _merge_emit(self, kk: np.ndarray, vv: np.ndarray,
                    bound: int) -> None:
        """Merge one table window with the overlay slice up to ``bound``
        (inclusive). Overlay wins ties; tombstones drop both. Appends
        live entries, ascending, to the buffer — the common case (no
        overlay entry in range) passes the window through untouched."""
        okeys, overlay = self._okeys, self.snap.overlay
        now = clock.now()
        oend = self._oi
        while oend < len(okeys) and okeys[oend] <= bound:
            oend += 1
        if oend == self._oi:  # fast path: pure table window
            self._push(kk, vv)
            return
        ti = 0
        out_k: list[int] = []
        out_v: list[np.ndarray] = []
        while True:
            okey = okeys[self._oi] if self._oi < oend else None
            tkey = int(kk[ti]) if ti < len(kk) else None
            if okey is None and tkey is None:
                break
            if tkey is None or (okey is not None and okey <= tkey):
                if okey == tkey:
                    ti += 1  # overlay shadows the table entry
                self._oi += 1
                e = overlay[okey]
                if not entry_dead(e, now):
                    out_k.append(okey)
                    out_v.append(np.asarray(e.val, np.uint32))
            else:
                out_k.append(tkey)
                out_v.append(vv[ti])
                ti += 1
        if out_k:
            self._push(
                np.array(out_k, np.uint64),
                np.stack(out_v).astype(np.uint32, copy=False),
            )

    def _fill(self, n: int) -> None:
        """Pull windows until ``n`` live entries are buffered or the view
        is exhausted."""
        parts = self.snap.partitions
        while self._buffered < n and not self._done:
            if self._interrupt is not None:
                self._interrupt()
            if self._pi >= len(parts):
                # every partition drained: flush the overlay tail
                self._merge_emit(
                    np.zeros(0, np.uint64),
                    np.zeros((0, self.vw), np.uint32),
                    (1 << 64) - 1,
                )
                self._done = True
                return
            if self._stream is None:
                self._open_stream()
            kk, vv, pdone = self._next_window()
            if pdone:
                # partition exhausted: overlay entries below the next
                # partition's range can all be emitted
                bound = self._spans[self._pi][1] - 1
                self._pi += 1
                self._stream = None
            elif len(kk):
                bound = int(kk[-1])
            else:
                continue  # dead window mid-partition: nothing emittable
            self._merge_emit(kk, vv, bound)

"""Distributed RemixDB: partitions sharded over the mesh, queries routed
with shard_map + all_to_all.

Each device owns one key-range partition shard (runs + REMIX). A global
query batch is routed by key range: sort-by-owner on the source shard, an
all_to_all exchanges query slices, every shard answers its slice with the
batched REMIX seek/get, and a second all_to_all returns results. This is
the paper's partitioned store (§4) mapped onto a TPU pod's ICI fabric.

For the dry-run the per-shard state is a stacked (n_shards, ...) pytree fed
through shard_map; keys are range-partitioned by the high bits so routing
is arithmetic, not a directory lookup.
"""
from __future__ import annotations

import bisect
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import keys as CK
from repro.core import query as Q
from repro.core.remix import Remix
from repro.core.runs import RunSet


def shard_axes(mesh: Mesh) -> tuple[str, ...]:
    """All mesh axes — the store shards over the full device fabric."""
    return tuple(mesh.axis_names)


def route_host(lows, keys) -> np.ndarray:
    """Host-side range routing: owner index per key.

    ``lows`` are the sorted inclusive lower bounds of the ranges (the
    first covers everything below it too); one vectorized searchsorted
    routes a whole batch. This is the single routing primitive shared by
    ``RemixDB`` (partition routing in ``flush``/``get_batch``/
    ``scan_batch``) and ``serve.KVServeEngine`` (shard routing), so a
    sharded batch is split with the same arithmetic at every level.
    """
    lows = np.asarray(lows, np.uint64)
    keys = np.asarray(keys, np.uint64)
    return np.maximum(np.searchsorted(lows, keys, side="right") - 1, 0)


def route_one(parts_or_lows, key: int) -> int:
    """Scalar :func:`route_host`: owning range index of one key.

    Accepts a sequence of partitions/shards (anything with ``.lo``) or
    raw lower bounds — the single routing rule shared by the store's
    point reads and the cursor's seek.
    """
    lows = [int(getattr(x, "lo", x)) for x in parts_or_lows]
    return max(0, bisect.bisect_right(lows, int(key)) - 1)


def partition_spans(lows) -> list[tuple[int, int]]:
    """``[lo, hi)`` key spans for sorted inclusive lower bounds.

    The companion of :func:`route_host`: each range's exclusive upper
    bound is the next range's lower bound (the last spans to 2**64).
    Shared by the store's scans and :class:`repro.db.cursor.RemixCursor`
    so partition/shard boundaries are computed by one rule everywhere.
    Python ints, not uint64: the final bound 2**64 must be representable.
    """
    lows = [int(x) for x in lows]
    return list(zip(lows, lows[1:] + [1 << 64]))


def abstract_state(cfg, n_shards: int):
    """ShapeDtypeStructs for the sharded store state (dry-run inputs)."""
    r, n, kw, vw, d = (
        cfg.runs_per_partition,
        cfg.entries_per_run,
        cfg.kw,
        cfg.vw,
        cfg.group_d,
    )
    slots = r * n + (r * n) // d * 0 + d  # view slots (+ padding slack)
    slots = ((r * n + d - 1) // d + 1) * d
    g = slots // d
    sds = jax.ShapeDtypeStruct
    remix = Remix(
        anchors=sds((n_shards, g, kw), jnp.uint32),
        cursors=sds((n_shards, g, r), jnp.int32),
        selectors=sds((n_shards, slots), jnp.uint8),
        n_entries=sds((n_shards,), jnp.int32),
        d=d,
    )
    runset = RunSet(
        keys=sds((n_shards, r, n, kw), jnp.uint32),
        vals=sds((n_shards, r, n, vw), jnp.uint32),
        seq=sds((n_shards, r, n), jnp.uint32),
        tomb=sds((n_shards, r, n), jnp.bool_),
        lens=sds((n_shards, r), jnp.int32),
    )
    return remix, runset


def _owner_of(keys_u32: jnp.ndarray, n_shards: int) -> jnp.ndarray:
    """Range partitioning by high key bits: owner = hi_word / (2^32/S)."""
    hi = keys_u32[..., 0]
    step = np.uint32(max(1, (1 << 32) // n_shards))
    return jnp.minimum((hi // step).astype(jnp.int32), n_shards - 1)


def make_sharded_get(cfg, mesh: Mesh):
    """Build the jitted distributed point-query step for the dry-run.

    queries: (Q_global, KW) uint32 sharded over all axes → (found, vals).
    """
    axes = shard_axes(mesh)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    qspec = P(axes)
    sspec = P(axes)  # state: leading shard dim over all axes

    def step(remix, runset, queries):
        def local(remix_l, runset_l, q_l):
            # drop the leading singleton shard dim
            remix_l = jax.tree.map(lambda x: x[0], remix_l)
            runset_l = jax.tree.map(lambda x: x[0], runset_l)
            nq, kw = q_l.shape
            owner = _owner_of(q_l, n_shards)
            # capacity-based dispatch (n_shards, C) — 2× slack over uniform
            cap = max(1, 2 * nq // n_shards)
            order = jnp.argsort(owner)
            so, sq = owner[order], q_l[order]
            counts = jnp.bincount(owner, length=n_shards)
            starts = jnp.cumsum(counts) - counts
            slot = jnp.arange(nq) - starts[so]
            ok = slot < cap
            slot_c = jnp.where(ok, slot, cap - 1)
            out_q = jnp.zeros((n_shards, cap, kw), q_l.dtype)
            out_q = out_q.at[so, slot_c].set(
                jnp.where(ok[:, None], sq, 0), mode="drop"
            )
            filled = jnp.zeros((n_shards, cap), bool).at[so, slot_c].set(
                ok, mode="drop"
            )
            # exchange: device receives its slice from every peer
            q_in = jax.lax.all_to_all(out_q, axes, 0, 0)  # (n_shards, C, KW)
            f_in = jax.lax.all_to_all(filled, axes, 0, 0)
            found, vals = Q.get(remix_l, runset_l, q_in.reshape(-1, kw))
            found = (found.reshape(n_shards, cap) & f_in)
            vals = vals.reshape(n_shards, cap, -1)
            # route answers back + un-permute to request order
            f_back = jax.lax.all_to_all(found, axes, 0, 0)
            v_back = jax.lax.all_to_all(vals, axes, 0, 0)
            f_sorted = jnp.where(ok, f_back[so, slot_c], False)
            v_sorted = jnp.where(ok[:, None], v_back[so, slot_c], 0)
            inv = jnp.argsort(order)
            return f_sorted[inv], v_sorted[inv]

        return jax.shard_map(
            local,
            mesh=mesh,
            in_specs=(
                jax.tree.map(lambda _: sspec, remix,
                             is_leaf=lambda x: hasattr(x, "shape")),
                jax.tree.map(lambda _: sspec, runset,
                             is_leaf=lambda x: hasattr(x, "shape")),
                qspec,
            ),
            out_specs=(qspec, qspec),
            check_vma=False,
        )(remix, runset, queries)

    return step, qspec


def build_demo_state(cfg, n_shards: int, seed: int = 0):
    """Concrete small sharded store for tests (n_shards = real devices)."""
    from repro.core.remix import build_remix
    from repro.core.runs import make_run

    rng = np.random.default_rng(seed)
    remixes, runsets = [], []
    span = (1 << 32) // n_shards
    for s in range(n_shards):
        runs = []
        lo = s * span << 32
        for r in range(cfg.runs_per_partition):
            kk = rng.choice(
                span * (1 << 6), size=cfg.entries_per_run, replace=False
            ).astype(np.uint64)
            kk = np.uint64(lo) + (kk << np.uint64(26))  # stay in shard range
            runs.append(make_run(np.sort(kk), seq=r, vw=cfg.vw))
        remix, runset = build_remix(runs, d=cfg.group_d)
        remixes.append(remix)
        runsets.append(runset)
    remix = jax.tree.map(lambda *x: jnp.stack(x), *remixes)
    runset = jax.tree.map(lambda *x: jnp.stack(x), *runsets)
    return remix, runset

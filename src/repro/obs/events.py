"""Bounded structured event log for background lifecycle transitions.

Flush, compaction round, WAL checkpoint/GC, file GC, partition promotion
and version publish used to happen silently (or via ``print``); each now
emits one :class:`Event` — a timestamp, a kind, and a flat dict of fields
(byte counts, durations, ids) — into a fixed-capacity ring buffer.

The ring is the in-process view (``RemixDB.events.list()``, newest last;
capacity is the ``event_log_capacity`` store knob). An optional JSONL
sink mirrors every event append-only to disk for post-mortem tooling;
sink failures are counted, never raised — observability must not take
down the store. ``seq`` is a monotonic per-log sequence number, so a
reader can detect how many events the ring dropped.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque


class Event:
    __slots__ = ("seq", "ts", "kind", "fields")

    def __init__(self, seq: int, ts: float, kind: str, fields: dict):
        self.seq = seq
        self.ts = ts
        self.kind = kind
        self.fields = fields

    def to_dict(self) -> dict:
        d = dict(seq=self.seq, ts=self.ts, kind=self.kind)
        d.update(self.fields)
        return d

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Event({self.seq}, {self.kind}, {self.fields})"


class EventLog:
    """Thread-safe ring buffer of :class:`Event` + optional JSONL sink."""

    def __init__(self, capacity: int = 256, jsonl_path=None):
        if capacity <= 0:
            raise ValueError("event log capacity must be positive")
        self.capacity = int(capacity)
        self._ring: deque[Event] = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._seq = 0
        self._sink = None
        self.sink_errors = 0
        if jsonl_path is not None:
            self._sink = open(jsonl_path, "a", buffering=1)

    def emit(self, kind: str, **fields) -> Event:
        ev = Event(0, time.time(), kind, fields)
        with self._lock:
            self._seq += 1
            ev.seq = self._seq
            self._ring.append(ev)
            sink = self._sink
        if sink is not None:
            try:
                sink.write(json.dumps(ev.to_dict(), default=str) + "\n")
            except Exception:
                self.sink_errors += 1
        return ev

    def list(self, kind: str | None = None) -> list[Event]:
        """Events currently in the ring, oldest first."""
        with self._lock:
            evs = list(self._ring)
        if kind is not None:
            evs = [e for e in evs if e.kind == kind]
        return evs

    def kinds(self) -> list[str]:
        """Distinct kinds in ring order of first appearance."""
        seen, out = set(), []
        for e in self.list():
            if e.kind not in seen:
                seen.add(e.kind)
                out.append(e.kind)
        return out

    def stats(self) -> dict:
        with self._lock:
            n, seq = len(self._ring), self._seq
        return dict(capacity=self.capacity, buffered=n, emitted=seq,
                    dropped=seq - n, sink_errors=self.sink_errors)

    def close(self) -> None:
        with self._lock:
            sink, self._sink = self._sink, None
        if sink is not None:
            try:
                sink.close()
            except Exception:
                self.sink_errors += 1


class NullEventLog:
    """No-op stand-in (``metrics=False`` disables event capture too)."""

    capacity = 0
    sink_errors = 0

    def emit(self, kind: str, **fields):
        return None

    def list(self, kind=None):
        return []

    def kinds(self):
        return []

    def stats(self) -> dict:
        return dict(capacity=0, buffered=0, emitted=0, dropped=0,
                    sink_errors=0)

    def close(self) -> None:
        pass


NULL_EVENTS = NullEventLog()

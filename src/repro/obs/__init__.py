"""Unified observability layer: metrics, tracing, structured events.

Three pillars, one package (see ``docs/OBSERVABILITY.md``):

* :mod:`repro.obs.metrics` — lock-cheap counters/gauges/log-bucketed
  histograms in a :class:`MetricsRegistry`; legacy ``stats()`` dicts are
  bit-compatible views over it, and snapshots render to Prometheus text.
* :mod:`repro.obs.tracing` — sampled per-batch span trees through the op
  executor down to cache/disk/CKB leaf spans; Chrome trace_event export.
* :mod:`repro.obs.events` — bounded ring of structured lifecycle events
  (flush, compaction, WAL GC, publish, promotion) + optional JSONL sink.
"""
from repro.obs.events import NULL_EVENTS, Event, EventLog, NullEventLog
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    MultiGauge,
    NULL_INSTRUMENT,
    diff_snapshots,
    load_snapshot,
    merge_snapshots,
    render_prometheus,
    save_snapshot,
)
from repro.obs.tracing import Sampler, Span, Trace, activate, current

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "MultiGauge",
    "NULL_INSTRUMENT", "diff_snapshots", "load_snapshot", "merge_snapshots",
    "render_prometheus", "save_snapshot",
    "Event", "EventLog", "NullEventLog", "NULL_EVENTS",
    "Sampler", "Span", "Trace", "activate", "current",
]

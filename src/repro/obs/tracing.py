"""Sampled op-lifecycle tracing: span trees over ``Executor.submit()``.

A :class:`Trace` is one tree of :class:`Span` s for one submitted batch:
``admission`` (backpressure wait) → ``queue`` (async pickup delay) →
``plan`` → per-stage/per-shard execution groups → leaf spans recorded at
the physical layers (``cache_fetch`` in the block cache, ``disk_read`` in
the SSTable reader, ``ckb_decode`` in the compressed-key-block reader).

Activation is a **thread-local**: the executor activates the batch's
trace around execution, and leaf sites ask :func:`current` — a single
``getattr`` on a ``threading.local`` — so the untraced hot path pays one
predictable branch, nothing else. Traces reach callers on
``BatchResult.trace`` (``Batch(trace=True)`` opt-in, or the
``trace_sample_rate`` knob sampling 1-in-N batches deterministically) and
export as Chrome ``trace_event`` JSON loadable in ``chrome://tracing`` /
Perfetto.

Coverage accounting: :meth:`Trace.leaf_coverage` is the fraction of the
root span's wall time covered by at least one instrumented child span —
Σ self-time (span duration − Σ child durations) over all non-root spans,
divided by the root duration. The acceptance bar (≥ 0.9 on a mixed
cross-shard batch) means at most 10% of a traced batch's latency is
unattributed glue.
"""
from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager

_tls = threading.local()

now = time.perf_counter


class Span:
    __slots__ = ("name", "t0", "t1", "args", "children")

    def __init__(self, name: str, t0: float, args: dict | None = None):
        self.name = name
        self.t0 = t0
        self.t1 = t0
        self.args = args or {}
        self.children: list[Span] = []

    @property
    def duration(self) -> float:
        return max(0.0, self.t1 - self.t0)

    def self_time(self) -> float:
        return max(0.0, self.duration - sum(c.duration for c in self.children))

    def walk(self):
        yield self
        for c in self.children:
            yield from c.walk()

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Span({self.name}, {self.duration * 1e6:.1f}us, " \
               f"{len(self.children)} children)"


class Trace:
    """One span tree. Not thread-safe across concurrent writers — the
    executor runs one batch's stages on one thread, which is the only
    writer while the trace is activated there."""

    def __init__(self, name: str = "batch", args: dict | None = None):
        self.root = Span(name, now(), args)
        self._stack = [self.root]
        self.sampled = False  # set when chosen by trace_sample_rate

    # ---- recording ----
    @contextmanager
    def span(self, name: str, **args):
        sp = Span(name, now(), args)
        parent = self._stack[-1]
        parent.children.append(sp)
        self._stack.append(sp)
        try:
            yield sp
        finally:
            sp.t1 = now()
            self._stack.pop()

    def leaf(self, name: str, t0: float, t1: float, **args) -> Span:
        """Record an already-timed leaf span under the current parent."""
        sp = Span(name, t0, args)
        sp.t1 = t1
        self._stack[-1].children.append(sp)
        return sp

    def finish(self) -> "Trace":
        self.root.t1 = now()
        return self

    # ---- reading ----
    def spans(self) -> list[Span]:
        return list(self.root.walk())

    def find(self, name: str) -> list[Span]:
        return [s for s in self.spans() if s.name == name]

    def leaf_coverage(self) -> float:
        dur = self.root.duration
        if dur <= 0:
            return 1.0
        covered = sum(s.self_time() for s in self.spans() if s is not self.root)
        return min(1.0, covered / dur)

    def well_formed(self) -> bool:
        """Every span ends after it starts and nests inside its parent
        (small float slack for clock granularity)."""
        eps = 1e-9
        for s in self.spans():
            if s.t1 + eps < s.t0:
                return False
            for c in s.children:
                if c.t0 + eps < s.t0 - eps or c.t1 > s.t1 + eps:
                    return False
        return True

    # ---- export ----
    def to_chrome(self, pid: int = 1, tid: int = 1) -> dict:
        """Chrome ``trace_event`` JSON object format (``ph: "X"`` complete
        events, microsecond timestamps relative to the root start)."""
        base = self.root.t0
        events = []
        for s in self.spans():
            ev = dict(
                name=s.name, ph="X", pid=pid, tid=tid,
                ts=round((s.t0 - base) * 1e6, 3),
                dur=round(s.duration * 1e6, 3),
            )
            if s.args:
                ev["args"] = {k: _jsonable(v) for k, v in s.args.items()}
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def to_chrome_json(self, **kw) -> str:
        return json.dumps(self.to_chrome(**kw))

    def save_chrome(self, path, **kw) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(**kw), f, indent=1)


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


# ---------------- thread-local activation ----------------

def current() -> Trace | None:
    """The trace activated on this thread, or None (the untraced fast
    path: one thread-local getattr)."""
    return getattr(_tls, "trace", None)


@contextmanager
def activate(trace: Trace | None):
    """Make ``trace`` the thread's active trace for the duration (no-op
    when None). Leaf instrumentation in the io layer records into it."""
    if trace is None:
        yield None
        return
    prev = getattr(_tls, "trace", None)
    _tls.trace = trace
    try:
        yield trace
    finally:
        _tls.trace = prev


class Sampler:
    """Deterministic 1-in-N batch sampler for ``trace_sample_rate``.

    ``rate`` is the target fraction of batches traced; sampling is
    counter-based (every round(1/rate)-th batch) so runs are reproducible
    and the first batch of a fresh process is always sampled — the one a
    human is usually staring at.
    """

    def __init__(self, rate: float = 0.0):
        rate = float(rate)
        if not 0.0 <= rate <= 1.0:
            raise ValueError("trace_sample_rate must be in [0, 1]")
        self.rate = rate
        self._every = 0 if rate == 0.0 else max(1, round(1.0 / rate))
        self._n = 0
        self._lock = threading.Lock()

    def should_sample(self) -> bool:
        if self._every == 0:
            return False
        with self._lock:
            n = self._n
            self._n += 1
        return n % self._every == 0

"""Lock-cheap metrics registry: counters, gauges, log-bucketed histograms.

One :class:`MetricsRegistry` per component (store, serving engine, shared
block cache); every pre-existing ad-hoc counter in ``io/blockcache.py``,
``db/store.py``, ``db/wal.py``, ``db/executor.py`` … is now an instrument
registered here, and the legacy ``stats()`` dicts are thin views reading
instrument values back out (bit-compatible keys, equality-tested in
``tests/test_obs.py``).

Design points:

* **Lock-cheap.** Each counter/histogram carries its own ``threading.Lock``
  taken only for the few ns of the update — there is no registry-wide lock
  on the hot path, and uncontended CPython lock acquire is ~100 ns, far
  below the µs-scale block/batch operations being counted.
  ``engine_bench`` asserts the end-to-end cost: metrics-on throughput must
  stay ≥ 0.95x metrics-off.
* **HDR-style fixed buckets.** Histograms use geometric bucket bounds
  fixed at construction (growth 2**1/4 ≈ 1.19 for latency, 2x for sizes),
  so ``observe`` is a ``bisect`` into a precomputed list plus one slot
  increment — no allocation, no rebucketing — and p50/p95/p99 read-out is
  a cumulative walk with a geometric-midpoint estimate whose relative
  error is bounded by the growth factor.
* **Labels.** Instruments are keyed by ``(name, sorted(label items))``;
  a registry can also carry default labels (e.g. ``shard="2"``) applied
  to every instrument it creates, and snapshots can be merged with extra
  labels stamped per source — that is how ``KVServeEngine.metrics()``
  builds one per-shard-labelled view over many per-store registries.
* **Null instruments.** A registry constructed with ``enabled=False``
  hands out shared no-op instruments and snapshots to nothing, so the
  ``metrics=False`` store knob removes even the lock acquires.

Snapshot format (also the JSON artifact / obstool / Prometheus input): a
dict ``{"metrics": [sample, ...]}`` where each sample is a plain dict —
``{"name", "type", "labels", ...}`` plus ``value`` for counters/gauges or
``count/sum/min/max/p50/p95/p99/buckets`` for histograms. ``buckets`` is a
list of ``[upper_bound, cumulative_count]`` pairs (only buckets that grew,
plus the +Inf total), directly renderable as Prometheus ``_bucket`` lines.
"""
from __future__ import annotations

import json
import math
import threading
from bisect import bisect_left


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotonic counter. ``inc`` takes the instrument's own lock only."""

    __slots__ = ("name", "labels", "_lock", "_v")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._v = 0

    def inc(self, n: int | float = 1) -> None:
        if n < 0:
            raise ValueError("counters are monotonic; inc(n >= 0)")
        with self._lock:
            self._v += n

    @property
    def value(self):
        return self._v

    def sample(self) -> dict:
        return dict(name=self.name, type="counter", labels=dict(self.labels),
                    value=self._v)


class Gauge:
    """Point-in-time value: ``set()`` explicitly, or a callback read at
    snapshot time (used for derived values like queue depth, cached
    bytes, live versions — no write-path cost at all)."""

    __slots__ = ("name", "labels", "_lock", "_v", "_fn")

    def __init__(self, name: str, labels: dict, fn=None):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._v = 0
        self._fn = fn

    def set(self, v) -> None:
        with self._lock:
            self._v = v

    def inc(self, n=1) -> None:
        with self._lock:
            self._v += n

    def dec(self, n=1) -> None:
        with self._lock:
            self._v -= n

    @property
    def value(self):
        if self._fn is not None:
            try:
                return self._fn()
            except Exception:
                return 0
        return self._v

    def sample(self) -> dict:
        return dict(name=self.name, type="gauge", labels=dict(self.labels),
                    value=self.value)


class MultiGauge:
    """Callback gauge fanning out to many label sets at snapshot time.

    The callback returns ``[(labels_dict, value), ...]`` — used for
    per-partition cold counters and per-table CKB memo sizes, where the
    label population (partitions, tables) changes as versions turn over.
    """

    __slots__ = ("name", "labels", "_fn")

    def __init__(self, name: str, labels: dict, fn):
        self.name = name
        self.labels = labels
        self._fn = fn

    def samples(self) -> list[dict]:
        try:
            rows = self._fn()
        except Exception:
            return []
        out = []
        for lbl, v in rows:
            merged = dict(self.labels)
            merged.update({str(k): str(x) for k, x in lbl.items()})
            out.append(dict(name=self.name, type="gauge", labels=merged,
                            value=v))
        return out


def latency_bounds() -> list[float]:
    """Geometric bounds 1 µs → ~537 s, growth 2**1/4 (~19%/bucket)."""
    g = 2.0 ** 0.25
    b, out = 1e-6, []
    while b < 600.0:
        out.append(b)
        b *= g
    return out


def bytes_bounds() -> list[float]:
    """Power-of-two byte-size bounds 1 B → 1 TiB."""
    return [float(1 << i) for i in range(41)]


_BOUND_KINDS = {"latency": latency_bounds, "bytes": bytes_bounds}


class Histogram:
    """Fixed log-bucketed histogram with p50/p95/p99/max readout.

    ``observe`` is bisect + increment under the instrument lock; exact
    ``sum``/``min``/``max`` are tracked alongside so max is not a bucket
    estimate. Quantiles interpolate the geometric midpoint of the bucket
    containing the target rank (relative error bounded by bucket growth).
    """

    __slots__ = ("name", "labels", "kind", "_lock", "_bounds", "_counts",
                 "_count", "_sum", "_min", "_max")

    def __init__(self, name: str, labels: dict, kind: str = "latency",
                 bounds: list[float] | None = None):
        self.name = name
        self.labels = labels
        self.kind = kind
        self._bounds = list(bounds) if bounds is not None else _BOUND_KINDS[kind]()
        self._lock = threading.Lock()
        self._counts = [0] * (len(self._bounds) + 1)  # last = overflow
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = 0.0

    def observe(self, v) -> None:
        v = float(v)
        i = bisect_left(self._bounds, v)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        return self._count

    def percentile(self, q: float) -> float:
        """Estimate the q-quantile (q in [0, 1]) from bucket counts."""
        with self._lock:
            total = self._count
            if total == 0:
                return 0.0
            rank = q * total
            acc = 0
            for i, c in enumerate(self._counts):
                acc += c
                if acc >= rank and c:
                    if i == 0:
                        lo, hi = self._bounds[0] / 2.0, self._bounds[0]
                    elif i == len(self._bounds):
                        lo, hi = self._bounds[-1], max(self._max, self._bounds[-1])
                    else:
                        lo, hi = self._bounds[i - 1], self._bounds[i]
                    est = math.sqrt(lo * hi) if lo > 0 else hi / 2.0
                    # clamp to observed range: beats the bucket estimate
                    # at the tails and makes p100 == max exactly
                    return min(max(est, self._min), self._max)
            return self._max

    def summary(self) -> dict:
        return dict(
            count=self._count,
            sum=self._sum,
            min=0.0 if self._count == 0 else self._min,
            max=self._max,
            p50=self.percentile(0.50),
            p95=self.percentile(0.95),
            p99=self.percentile(0.99),
        )

    def sample(self) -> dict:
        with self._lock:
            counts = list(self._counts)
        s = self.summary()
        buckets, acc = [], 0
        for i, c in enumerate(counts):
            acc += c
            if c:
                le = self._bounds[i] if i < len(self._bounds) else math.inf
                buckets.append([le, acc])
        if not buckets or math.isfinite(buckets[-1][0]):
            buckets.append([math.inf, acc])
        s.update(name=self.name, type="histogram", labels=dict(self.labels),
                 buckets=buckets)
        return s


class _NullInstrument:
    """Shared no-op stand-in handed out by a disabled registry."""

    __slots__ = ()
    name = "null"
    labels: dict = {}
    value = 0
    count = 0

    def inc(self, n=1):
        pass

    def dec(self, n=1):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass

    def percentile(self, q):
        return 0.0

    def summary(self):
        return dict(count=0, sum=0.0, min=0.0, max=0.0, p50=0.0, p95=0.0,
                    p99=0.0)

    def sample(self):
        return dict(name="null", type="counter", labels={}, value=0)


NULL_INSTRUMENT = _NullInstrument()


class MetricsRegistry:
    """Get-or-create home for named instruments.

    Creation takes the registry lock; the returned instrument is cached by
    the call site, so steady-state updates never touch the registry again.
    ``default_labels`` are stamped on every instrument created here.
    """

    def __init__(self, enabled: bool = True, labels: dict | None = None):
        self.enabled = bool(enabled)
        self.default_labels = dict(labels or {})
        self._lock = threading.Lock()
        self._instruments: dict = {}
        self._multi: list[MultiGauge] = []

    def _merge_labels(self, labels: dict) -> dict:
        merged = dict(self.default_labels)
        merged.update(labels)
        return merged

    def _get_or_create(self, cls, name: str, labels: dict, *args, **kw):
        if not self.enabled:
            return NULL_INSTRUMENT
        labels = self._merge_labels(labels)
        key = (name, _label_key(labels))
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = cls(name, labels, *args, **kw)
                self._instruments[key] = inst
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get_or_create(Counter, name, labels)

    def gauge(self, name: str, fn=None, **labels) -> Gauge:
        g = self._get_or_create(Gauge, name, labels, fn)
        if fn is not None and isinstance(g, Gauge):
            g._fn = fn  # re-registering a callback refreshes it
        return g

    def multi_gauge(self, name: str, fn, **labels) -> MultiGauge:
        """Register a snapshot-time callback yielding many label sets."""
        if not self.enabled:
            return NULL_INSTRUMENT
        mg = MultiGauge(name, self._merge_labels(labels), fn)
        with self._lock:
            self._multi.append(mg)
        return mg

    def histogram(self, name: str, kind: str = "latency",
                  bounds: list[float] | None = None, **labels) -> Histogram:
        return self._get_or_create(Histogram, name, labels, kind, bounds)

    def snapshot(self, extra_labels: dict | None = None) -> dict:
        """Point-in-time dump of every instrument as plain dicts."""
        with self._lock:
            insts = list(self._instruments.values())
            multi = list(self._multi)
        samples = []
        for inst in insts:
            samples.append(inst.sample())
        for mg in multi:
            samples.extend(mg.samples())
        if extra_labels:
            ex = {str(k): str(v) for k, v in extra_labels.items()}
            for s in samples:
                merged = dict(ex)
                merged.update(s["labels"])
                s["labels"] = merged
        samples.sort(key=lambda s: (s["name"], sorted(s["labels"].items())))
        return {"metrics": samples}


def merge_snapshots(*parts) -> dict:
    """Concatenate snapshots; each part is a snapshot dict or a
    ``(snapshot, extra_labels)`` pair whose labels stamp every sample —
    how per-shard registries become one labelled serving-node view."""
    samples = []
    for part in parts:
        extra = None
        if isinstance(part, tuple):
            part, extra = part
        for s in part.get("metrics", []):
            s = dict(s, labels=dict(s["labels"]))
            if extra:
                merged = {str(k): str(v) for k, v in extra.items()}
                merged.update(s["labels"])
                s["labels"] = merged
            samples.append(s)
    samples.sort(key=lambda s: (s["name"], sorted(s["labels"].items())))
    return {"metrics": samples}


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def render_prometheus(snapshot: dict) -> str:
    """Prometheus text exposition format (0.0.4) for a snapshot."""
    by_name: dict[str, list[dict]] = {}
    for s in snapshot.get("metrics", []):
        by_name.setdefault(s["name"], []).append(s)
    lines = []
    for name in sorted(by_name):
        group = by_name[name]
        typ = group[0]["type"]
        lines.append(f"# TYPE {name} {typ}")
        for s in group:
            lbl = s["labels"]
            if typ == "histogram":
                for le, acc in s["buckets"]:
                    b = dict(lbl, le=("+Inf" if math.isinf(le) else repr(le)))
                    lines.append(f"{name}_bucket{_fmt_labels(b)} {acc}")
                lines.append(f"{name}_sum{_fmt_labels(lbl)} {s['sum']}")
                lines.append(f"{name}_count{_fmt_labels(lbl)} {s['count']}")
            else:
                lines.append(f"{name}{_fmt_labels(lbl)} {s['value']}")
    return "\n".join(lines) + "\n"


def _sample_key(s: dict) -> tuple:
    return (s["name"], _label_key(s["labels"]))


def diff_snapshots(before: dict, after: dict) -> dict:
    """Per-sample delta (after − before) for counters and histogram
    count/sum; gauges report (before, after). Samples only in one side
    are marked added/removed. Powers ``tools/obstool.py diff``."""
    b = {_sample_key(s): s for s in before.get("metrics", [])}
    a = {_sample_key(s): s for s in after.get("metrics", [])}
    rows = []
    for key in sorted(set(b) | set(a)):
        sb, sa = b.get(key), a.get(key)
        ref = sa or sb
        row = dict(name=ref["name"], labels=dict(ref["labels"]),
                   type=ref["type"])
        if sb is None:
            row["status"] = "added"
            rows.append(row)
            continue
        if sa is None:
            row["status"] = "removed"
            rows.append(row)
            continue
        if ref["type"] == "histogram":
            row["count_delta"] = sa["count"] - sb["count"]
            row["sum_delta"] = sa["sum"] - sb["sum"]
            row["p50"] = sa["p50"]
            row["p99"] = sa["p99"]
        elif ref["type"] == "counter":
            row["delta"] = sa["value"] - sb["value"]
        else:
            row["before"] = sb["value"]
            row["after"] = sa["value"]
        rows.append(row)
    return {"diff": rows}


def save_snapshot(snapshot: dict, path) -> None:
    with open(path, "w") as f:
        json.dump(snapshot, f, indent=1, default=float)


def load_snapshot(path) -> dict:
    with open(path) as f:
        return json.load(f)
